"""Analytic per-step cost models for the roofline (DESIGN.md SS'Roofline').

XLA's ``cost_analysis()`` counts while-loop (lax.scan) bodies ONCE, so for
scan-over-layers models it undercounts FLOPs/bytes by ~L.  The roofline
therefore uses analytic compute/memory terms (exact closed forms from the
config + shape), and HLO-parsed collectives corrected by while trip counts
(hlo_analysis.collective_summary(..., trip_aware=True)).

Conventions: MACs counted as 2 FLOPs; backward = 2x forward for matmuls;
attention counts the causal 1/2 factor; MoE counts active experts only.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.configs.base import ArchConfig, InputShape
from repro.models import count_params, padded_vocab
from repro.models.transformer import num_superblocks, superblock_kinds


def _attn_flops_per_layer(cfg, B, S, kv_len, window, kind) -> float:
    """Score + value matmul flops for one attention layer."""
    H, hd = cfg.num_heads, cfg.head_dim
    if kind == "decode":
        ctx = min(window, kv_len) if window else kv_len
        return 2.0 * 2.0 * B * H * hd * ctx  # q*K^T + p*V for 1 token
    ctx = min(window, S) if window else S
    # causal: average context ~ ctx/2 (window caps it)
    avg = ctx / 2.0 if not window else max(window / 2.0, 1.0)
    return 2.0 * 2.0 * B * S * H * hd * avg


def _layer_flops(cfg: ArchConfig, B: int, S: int, kv_len: int, kind: str) -> float:
    """Forward FLOPs of ONE superblock for B x S tokens."""
    d = cfg.d_model
    total = 0.0
    for bkind, window in superblock_kinds(cfg):
        if bkind == "attn":
            H, K, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
            proj = 2.0 * B * S * d * (2 * H * hd + 2 * K * hd)
            total += proj + _attn_flops_per_layer(cfg, B, S, kv_len, window, kind)
            if cfg.is_moe:
                act = cfg.experts_per_token + cfg.num_shared_experts
                total += 2.0 * B * S * (d * cfg.num_experts  # router
                                        + act * 3 * d * cfg.d_ff)
            else:
                total += 2.0 * B * S * 3 * d * cfg.d_ff
        elif bkind == "mamba":
            d_in = cfg.ssm_expand * d
            ds = cfg.ssm_state
            proj = 2.0 * B * S * d * (2 * d_in + 2 * ds + d_in // cfg.ssm_head_dim)
            ssd = 2.0 * B * S * d_in * 2 * ds          # state update + output
            total += proj + ssd + 2.0 * B * S * d_in * d  # out_proj
        elif bkind == "mlstm":
            d_in = 2 * d
            total += 2.0 * B * S * (d * 2 * d_in + 3 * d_in * d_in + d_in * d)
            hd = d_in // cfg.num_heads
            total += 2.0 * B * S * cfg.num_heads * (2 * hd * hd)
        elif bkind == "slstm":
            hd = d // cfg.num_heads
            total += 2.0 * B * S * (4 * d * d + 4 * cfg.num_heads * hd * hd + d * d)
    # zamba2 shared block applied once per superblock
    from repro.models.transformer import has_shared_block
    if has_shared_block(cfg):
        H, K, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
        d_ff = cfg.d_ff if cfg.d_ff > 0 else 4 * d
        total += 2.0 * B * S * (d * (2 * H * hd + 2 * K * hd) + 3 * d * d_ff)
        total += _attn_flops_per_layer(cfg, B, S, kv_len, 0, kind)
    return total


def step_flops(cfg: ArchConfig, shape: InputShape) -> float:
    """Global fwd(+bwd for train) FLOPs for one step of this shape."""
    B = shape.global_batch
    kind = shape.kind
    S = 1 if kind == "decode" else shape.seq_len
    kv_len = shape.seq_len
    V = padded_vocab(cfg)
    d = cfg.d_model

    if cfg.is_encdec:
        # decoder layers are plain attention blocks (no superblock pattern)
        H, K, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
        proj = 2.0 * B * S * d * (2 * H * hd + 2 * K * hd)
        dec = cfg.num_layers * (
            proj
            + _attn_flops_per_layer(cfg, B, S, kv_len, cfg.sliding_window, kind)
            + 2.0 * B * S * 3 * d * cfg.d_ff
            + proj  # cross-attn projections
        )
        core = dec
    else:
        n_super = num_superblocks(cfg)
        core = n_super * _layer_flops(cfg, B, S, kv_len, kind)
    if cfg.is_encdec:
        # encoder over the frontend frames (full bidirectional attention)
        Te = cfg.frontend_tokens
        H, K, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
        enc = cfg.encoder_layers * (
            2.0 * B * Te * (d * (2 * H * hd + 2 * K * hd) + 3 * d * cfg.d_ff)
            + 2.0 * 2.0 * B * Te * H * hd * Te
        )
        # cross attention per decoder layer
        core += enc + cfg.num_layers * 2.0 * 2.0 * B * S * H * hd * Te
    emb = 2.0 * B * S * d * V  # unembed matmul (embed lookup ~free)
    fwd = core + emb
    if kind == "train":
        return 3.0 * fwd  # bwd = 2x fwd
    return fwd


def step_hbm_bytes(cfg: ArchConfig, shape: InputShape, *, model_shard: int,
                   data_shard: int, weight_shard_extra: int = 1) -> float:
    """Per-device HBM traffic lower bound for one step.

    train:  params read twice (fwd+bwd) + grads written + Adam moments R/W
            + activation traffic with remat (~2x fwd writes+reads).
    serve:  weights read once + KV cache read(+write) + activations.
    """
    p_dtype = jnp.dtype(cfg.param_dtype).itemsize
    n_params = count_params(cfg)
    B, S = shape.global_batch, shape.seq_len
    d = cfg.d_model
    act_bytes = 2  # bf16 activations

    if shape.kind == "train":
        p_local = n_params * p_dtype / model_shard
        tokens_local = B * S / data_shard
        L = cfg.num_layers + cfg.encoder_layers
        # ~12 activation tensors of size (tokens, d) per layer, x2 for remat
        act = 2 * 12 * tokens_local * d * act_bytes * L
        return 3 * p_local + 3 * p_local + act  # params fwd/bwd/gradW + moments
    # serve
    shard = model_shard * data_shard * weight_shard_extra
    p_local = n_params * p_dtype / shard
    if shape.kind == "prefill":
        tokens_local = B * S / data_shard
        L = cfg.num_layers + cfg.encoder_layers
        act = 12 * tokens_local * d * act_bytes * L
        return p_local + act
    # decode: weights + full KV/state read per token
    cache = _cache_bytes(cfg, shape)
    return p_local + cache / (model_shard * data_shard)


def _cache_bytes(cfg, shape) -> float:
    B, S = shape.global_batch, shape.seq_len
    total = 0.0
    kv_itemsize = 1 if cfg.kv_cache_dtype == "int8" else 2
    if cfg.is_encdec:
        T = min(cfg.sliding_window, S) if cfg.sliding_window else S
        self_c = cfg.num_layers * 2 * B * T * cfg.num_kv_heads * cfg.head_dim * kv_itemsize
        cross = cfg.num_layers * 2 * B * cfg.frontend_tokens * \
            cfg.num_kv_heads * cfg.head_dim * 2
        return self_c + cross
    n_super = num_superblocks(cfg)
    for bkind, window in superblock_kinds(cfg):
        if bkind == "attn":
            T = min(window, S) if window else S
            total += (n_super * 2 * B * T * cfg.num_kv_heads
                      * cfg.head_dim * kv_itemsize)
        elif bkind == "mamba":
            d_in = cfg.ssm_expand * cfg.d_model
            H = d_in // cfg.ssm_head_dim
            total += n_super * B * H * cfg.ssm_head_dim * cfg.ssm_state * 4
        elif bkind in ("mlstm", "slstm"):
            d_in = 2 * cfg.d_model
            hd = d_in // cfg.num_heads
            total += n_super * B * cfg.num_heads * hd * hd * 4
    from repro.models.transformer import has_shared_block
    if has_shared_block(cfg):
        total += n_super * 2 * B * S * cfg.num_kv_heads * cfg.head_dim * 2
    if cfg.is_encdec:
        total += cfg.num_layers * 2 * B * cfg.frontend_tokens * \
            cfg.num_kv_heads * cfg.head_dim * 2
    return total
