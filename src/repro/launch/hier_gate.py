"""The hierarchical sharded-sync gate: compile one two-level step and
check its per-link byte accounting against the HLO (DESIGN.md §17).

Shared harness for the ``benchmarks.run --smoke`` "hier" gate and
``tests/test_hier_bytes.py`` — run in a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` so the CPU backend
has a real (pod=2, data=4) mesh to emit collectives on:

    python -m repro.launch.hier_gate

prints one ``HIER ...`` line and exits non-zero unless the compiled
module's per-link injected collective bytes (ICI vs DCN, classified by
``replica_groups`` pod-block membership) match the statically planned
``CommSchedule`` accounting: the intra-pod gradient reduce-scatters plus
the deferred head all-gather on the ICI, and only owned-shard-sized
cross-pod exchanges on the DCN.  It also reports
``hier_exposed_dcn_ratio`` — the DCN share of the exposed wire time-less
bytes over one full phase cycle — which ``benchmarks/hier_check.py``
records into the BENCH snapshot under the trajectory gate.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.hlo_analysis import collective_bytes_by_link

# the metric pmeans / grad-norm psums are 4-byte scalars; anything the
# schedule accounts for is a full bucket or shard
MIN_BYTES = 1024
# XLA's all-reduce combiner may fold the scalar grad-norm psum into a
# same-group bucket all-reduce, and arena padding rounds shard slices up
REL_TOL = 0.02
ABS_TOL = 2048.0


def build_trainer(
    *,
    arch: str = "gpt2-paper",
    vocab_size: int = 256,
    seq_len: int = 32,
    global_batch: int = 8,
    interval: int = 4,
    pod_interval: int = 2,
    n_pods: int = 2,
    sync: str = "sharded",
):
    from jax.sharding import Mesh

    from repro.configs import get_reduced
    from repro.data import DataConfig, make_loader
    from repro.models import build_model
    from repro.optim import adamw
    from repro.train.trainer import TrainConfig, Trainer

    devices = np.array(jax.devices()).reshape(n_pods, -1)
    mesh = Mesh(devices, ("pod", "data"))
    cfg = get_reduced(arch).with_(vocab_size=vocab_size)
    model = build_model(cfg)
    tc = TrainConfig(
        compressor="covap", interval=interval, bucket_bytes=1 << 14,
        max_buckets=32, log_every=10 ** 9, sync=sync,
        pod_interval=pod_interval,
    )
    trainer = Trainer(model, adamw(1e-3), tc, mesh=mesh,
                      dp_axes=("pod", "data"))
    state = trainer.init_state(jax.random.PRNGKey(0))
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=seq_len,
                    global_batch=global_batch)
    batch = next(iter(make_loader(dc)))
    return trainer, state, batch


def planned_bytes_by_link(fn) -> dict[str, float]:
    """Injected bytes the compiled phase body should move per link: the
    grad schedule's exposed calls, its deferred head all-gather (the
    settling gather is phase-independent, so this phase's deferred bytes
    equal the previous phase's), and the cross-pod reconcile calls."""
    out: dict[str, float] = {}

    def _acc(d):
        for link, v in d.items():
            out[link] = out.get(link, 0.0) + v

    _acc(fn.comm_schedule.exposed_bytes_by_link())
    _acc(fn.comm_schedule.deferred_bytes_by_link())
    if fn.pod_schedule is not None:
        _acc(fn.pod_schedule.exposed_bytes_by_link())
    return out


def compile_and_check(trainer=None, state=None, batch=None, *,
                      phase: int = 0, **kw) -> dict:
    """Compile ``trainer``'s hierarchical phase executable (or build the
    default (2, 4) one) and compare per-link schedule bytes against the
    optimized HLO's replica-group-classified collective bytes."""
    if trainer is None:
        trainer, state, batch = build_trainer(**kw)
    fn = trainer._phase_fn(phase)
    hlo = fn.lower(
        state["params"], state["opt"], state["comp"], batch, jnp.int32(0)
    ).compile().as_text()
    n_pods = trainer.mesh.shape["pod"]
    n_devices = len(trainer.mesh.devices.flat)
    hlo_by_link = collective_bytes_by_link(
        hlo, intra_world=n_devices // n_pods, min_bytes=MIN_BYTES,
        world=n_devices,
    )
    planned = planned_bytes_by_link(fn)
    rel = {}
    for link in set(planned) | set(hlo_by_link):
        p, h = planned.get(link, 0.0), hlo_by_link.get(link, 0.0)
        err = abs(h - p)
        rel[link] = 0.0 if err <= ABS_TOL else (err / p if p else float("inf"))
    return {
        "schedule": planned,
        "hlo": hlo_by_link,
        "rel_err": rel,
        "match": all(v <= REL_TOL for v in rel.values()),
    }


def exposed_dcn_ratio(trainer) -> float:
    """DCN share of the exposed wire bytes over one full (lcm) phase
    cycle — the headline number of the two-level decomposition: only
    owned-shard exchanges touch the slow link, so this should sit well
    below the DCN's share of a flat all-reduce."""
    ici = dcn = 0.0
    for s in trainer.schedules():
        by_link = s.exposed_wire_bytes_by_link(trainer.dp_world)
        ici += by_link.get("ici", 0.0)
        dcn += by_link.get("dcn", 0.0)
    total = ici + dcn
    return dcn / total if total else 0.0


def main() -> None:
    trainer, state, batch = build_trainer()
    r = compile_and_check(trainer, state, batch)
    ratio = exposed_dcn_ratio(trainer)
    print(
        f"HIER ici_schedule={r['schedule'].get('ici', 0.0):.0f} "
        f"ici_hlo={r['hlo'].get('ici', 0.0):.0f} "
        f"dcn_schedule={r['schedule'].get('dcn', 0.0):.0f} "
        f"dcn_hlo={r['hlo'].get('dcn', 0.0):.0f} "
        f"rel_ici={r['rel_err'].get('ici', 0.0):.4f} "
        f"rel_dcn={r['rel_err'].get('dcn', 0.0):.4f} "
        f"match={int(r['match'])} "
        f"hier_exposed_dcn_ratio={ratio:.4f}"
    )
    if not r["match"]:
        raise SystemExit(
            f"per-link schedule bytes diverge from compiled HLO: "
            f"schedule={r['schedule']} hlo={r['hlo']} rel_err={r['rel_err']}"
        )
    if not r["schedule"].get("dcn"):
        raise SystemExit(
            "hierarchical schedule planned no DCN bytes — the cross-pod "
            "exchange is missing from the phase plan"
        )


if __name__ == "__main__":
    main()
