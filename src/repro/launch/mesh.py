"""Production meshes.

Single pod: (data=16, model=16) = 256 chips (one v5e pod).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips; the 'pod' axis is an
additional **data-parallel** dimension (gradient sync crosses the DCN/ICI
pod boundary — exactly the communication COVAP compresses).

Defined as functions so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS before any jax import).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
    )


def dp_axes(*, multi_pod: bool = False) -> tuple[str, ...]:
    """The data-parallel (gradient-sync) axes of the production mesh."""
    return ("pod", "data") if multi_pod else ("data",)


def model_axis_size() -> int:
    return 16


def make_test_mesh(data: int = 4, model: int = 2):
    """Small mesh for multi-device CPU tests (spawned with fake devices)."""
    return jax.make_mesh(
        (data, model), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2,
    )
