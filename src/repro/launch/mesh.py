"""Production meshes.

Single pod: (data=16, model=16) = 256 chips (one v5e pod).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips; the 'pod' axis is an
additional **data-parallel** dimension (gradient sync crosses the DCN/ICI
pod boundary — exactly the communication COVAP compresses).

Defined as functions so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS before any jax import).
"""
from __future__ import annotations

import numpy as np

import jax


def make_mesh_compat(shape: tuple[int, ...], axes: tuple[str, ...]):
    """``jax.make_mesh`` with ``axis_types`` is a newer-jax API; older
    releases build a ``Mesh`` from a device array directly.  All axes are
    Auto either way."""
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(
            shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
        )
    n = int(np.prod(shape))
    devices = np.array(jax.devices()[:n]).reshape(shape)
    return jax.sharding.Mesh(devices, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_compat(shape, axes)


def dp_axes(*, multi_pod: bool = False) -> tuple[str, ...]:
    """The data-parallel (gradient-sync) axes of the production mesh."""
    return ("pod", "data") if multi_pod else ("data",)


def model_axis_size() -> int:
    return 16


def make_slice_mesh(n_slices: int, data: int = 8, model: int = 8):
    """Compile-only N-slice mesh for the multislice dry-run sweep
    (modeled on MaxText's multislice launch flow: every slice is one pod
    behind a DCN crossing).  Row-major (pod, data, model), so device rank
    ``r`` lives in pod ``r // (data * model)`` — the layout
    ``launch.hlo_analysis.group_link`` assumes.  ``n_slices <= 1``
    degenerates to the flat (data, model) mesh."""
    if n_slices <= 1:
        return make_mesh_compat((data, model), ("data", "model"))
    return make_mesh_compat((n_slices, data, model), ("pod", "data", "model"))


def make_test_mesh(data: int = 4, model: int = 2):
    """Small mesh for multi-device CPU tests (spawned with fake devices)."""
    return make_mesh_compat((data, model), ("data", "model"))
