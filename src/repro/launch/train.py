"""End-to-end training driver.

    python -m repro.launch.train --arch gpt2-paper --compressor covap \
        --steps 200 --seq-len 128 --global-batch 8 --interval auto

Runs a real training loop on the local backend (CPU here; the same builder
serves the production mesh via --mesh), with COVAP's measured-CCR interval
selection, metric logging, and checkpointing.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp

from repro import checkpoint
from repro.api import resolve_interval
from repro.configs import get_config, get_reduced
from repro.data import DataConfig, make_loader
from repro.models import build_model
from repro.optim import adamw, cosine_warmup, sgd
from repro.train.trainer import TrainConfig, Trainer


def pick_interval(args, cfg) -> int:
    """``repro.api``'s adaptive rule: I = ceil(analytic_ccr) (paper SS III.B),
    modelled on the paper's environment (30 Gbps cloud) for CPU-local runs."""
    choice = resolve_interval(
        args.interval, cfg,
        global_batch=args.global_batch, seq_len=args.seq_len,
        dp_world=max(args.dp_workers, 1),
    )
    if choice.auto:
        print(f"[ccr] analytic CCR={choice.ccr:.2f} -> interval I={choice.interval}")
    return choice.interval


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gpt2-paper")
    ap.add_argument("--reduced", action="store_true",
                    help="use the smoke-test REDUCED variant")
    ap.add_argument("--compressor", default="covap")
    ap.add_argument("--interval", default="auto")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--dp-workers", type=int, default=8,
                    help="modelled DP world size for CCR selection")
    ap.add_argument("--optimizer", default="adam", choices=["adam", "sgd"])
    ap.add_argument("--lr", type=float, default=1.5e-4)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--resume", action="store_true",
                    help="resume from the latest checkpoint in --ckpt-dir "
                         "(params, optimizer AND error-feedback state)")
    ap.add_argument("--overlap", default="post", choices=["post", "fused"],
                    help="gradient-sync placement: post-backward (default) "
                         "or fused into the backward pass (overlap engine)")
    ap.add_argument("--adaptive", action="store_true",
                    help="arm the adaptive runtime: re-plan the interval "
                         "online from measured CCR")
    ap.add_argument("--arena", action="store_true",
                    help="zero-copy gradient arena: statically-planned "
                         "flat bucket buffers + fused pack/EF/cast pass "
                         "(bitwise-equal payloads, fewer copies)")
    ap.add_argument("--sync", default="allreduce",
                    choices=["allreduce", "sharded"],
                    help="collective decomposition: all-reduce per bucket "
                         "(default) or reduce-scatter + deferred param "
                         "all-gather at the next step's head (sharded "
                         "optimizer step; halves the exposed wire volume)")
    ap.add_argument("--guards", action="store_true",
                    help="arm the resilience runtime (repro.resilience): "
                         "numeric guardrails on every step + the skip-step "
                         "-> EF-flush -> checkpoint-rewind recovery ladder "
                         "(rewind needs --ckpt-dir/--ckpt-every)")
    ap.add_argument("--inject-faults", default="",
                    help="deterministic chaos schedule, e.g. "
                         "'grad_nan@10,ef_blowup@20x2,kill@30' "
                         "(kind@step[xTIMES][*SCALE]; implies --guards)")
    ap.add_argument("--fault-seed", type=int, default=0,
                    help="seed for fault-site selection (reproducible chaos)")
    ap.add_argument("--history-out", default="")
    ap.add_argument("--telemetry-dir", default="",
                    help="arm the unified telemetry subsystem (repro.obs): "
                         "writes events.jsonl (streamed), metrics.prom, "
                         "metrics.json and trace.json into this directory")
    args = ap.parse_args()
    if args.interval == "adaptive":
        # mirror repro.api.fit: interval="adaptive" = analytic initial
        # pick + the online runtime armed
        args.adaptive = True

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    model = build_model(cfg)
    interval = pick_interval(args, cfg)

    if args.optimizer == "adam":
        opt = adamw(cosine_warmup(args.lr, args.steps // 10 + 1, args.steps))
    else:
        opt = sgd(args.lr, momentum=0.9)

    tc = TrainConfig(
        compressor=args.compressor, interval=interval,
        log_every=args.log_every, steps=args.steps,
        overlap=args.overlap, arena=args.arena, sync=args.sync,
    )
    tr = Trainer(model, opt, tc)
    print(f"[plan] {tr.plan.num_buckets} buckets, "
          f"target {tr.plan.bucket_bytes_target/1e6:.1f} MB, "
          f"{tr.num_phases} phase executable(s)")
    sr = tr.schedule_report()
    print(f"[schedule] mean {sr['mean_bytes_per_step']/1e6:.3f} MB/step "
          f"per worker (dense {sr['dense_bytes']/1e6:.3f} MB, "
          f"volume ratio {sr['volume_ratio']:.2f}x) — static plan, no tracing")
    if args.sync == "sharded":
        print(f"[schedule] sharded: "
              f"{sr['mean_exposed_wire_bytes_per_step']/1e6:.3f} MB/step "
              f"exposed wire (RS), "
              f"{sr['mean_deferred_bytes_per_step']/1e6:.3f} MB/step "
              f"deferred param AG riding the next forward pass")

    state = tr.init_state(jax.random.PRNGKey(0))
    if args.resume and args.ckpt_dir and checkpoint.latest_step(args.ckpt_dir) is not None:
        state, extra = checkpoint.restore_train_state(args.ckpt_dir, state)
        print(f"[ckpt] resumed step {state['step']} "
              f"(EF state: {extra.get('has_comp_state')}, "
              f"saved interval: {extra.get('interval')})")
        if not extra.get("comp_restored", True):
            print("[ckpt] WARNING: saved compressor state is structurally "
                  "incompatible with this config (EF on/off changed); "
                  "residual re-initialised")
        elif extra.get("interval") not in (None, interval):
            # the residual was accumulated under a different cadence:
            # cross the boundary through the runtime's transition logic
            state, rep = tr.replan(interval, state, step=state["step"],
                                   old_interval=extra["interval"])
            print(f"[ckpt] interval {extra['interval']} -> {interval}: "
                  f"residual {rep.policy} "
                  f"(norm {rep.norm_before:.3e} -> {rep.norm_after:.3e})")
    n_params = sum(int(x.size) for x in jax.tree.leaves(state["params"]))
    print(f"[model] {cfg.name}: {n_params/1e6:.1f}M params")

    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq_len,
                    global_batch=args.global_batch)
    loader = iter(make_loader(dc))

    autotune = None
    if args.adaptive:
        # one runtime for the whole run: chunked (checkpoint-every) calls
        # must not reset the controller's patience/cooldown or the trace
        from repro.runtime import AdaptiveRuntime

        autotune = AdaptiveRuntime(tr)
    resilience = None
    if args.guards or args.inject_faults:
        # one runtime across chunked run calls, like the AdaptiveRuntime:
        # the recovery ladder and fault firing counts must not reset at
        # checkpoint boundaries
        from repro.resilience import (
            GuardConfig, ResilienceRuntime, parse_fault_spec,
        )

        gcfg = GuardConfig(
            ckpt_dir=args.ckpt_dir or None,
            # the guard-owned rewind target rides the normal ckpt cadence
            ckpt_every=args.ckpt_every if args.ckpt_dir else 0,
        )
        plan = (
            parse_fault_spec(args.inject_faults, seed=args.fault_seed)
            if args.inject_faults else None
        )
        resilience = ResilienceRuntime(tr, guards=gcfg, faults=plan)
        msg = "guards armed (skip-step -> EF-flush -> rewind)"
        if plan is not None:
            msg += f"; injecting {len(plan.events)} fault(s): " \
                   f"{','.join(e.kind + '@' + str(e.step) for e in plan.events)}"
        print(f"[resilience] {msg}")
    telemetry = None
    if args.telemetry_dir:
        from repro.obs import Telemetry

        telemetry = Telemetry(args.telemetry_dir)
        print(f"[telemetry] streaming events to "
              f"{os.path.join(args.telemetry_dir, 'events.jsonl')}")
    t0 = time.perf_counter()
    done = 0
    while done < args.steps:
        chunk = args.steps - done
        if args.ckpt_dir and args.ckpt_every > 0:
            chunk = min(chunk, args.ckpt_every)
        state = tr.run(state, loader, steps=chunk, autotune=autotune,
                       telemetry=telemetry, guards=resilience)
        done += chunk
        if args.ckpt_dir and (args.ckpt_every > 0 or done >= args.steps):
            path = checkpoint.save_train_state(
                args.ckpt_dir, state, interval=tr.tc.interval,
            )
            print(f"[ckpt] saved {path} (params + opt + EF residuals)")
            if telemetry is not None:
                telemetry.events.emit(
                    "checkpoint", step=int(state["step"]), path=path
                )
    wall = time.perf_counter() - t0
    tokens = args.steps * args.global_batch * args.seq_len
    last = tr.history[-1]
    print(f"[done] {wall:.1f}s, {tokens/wall:.0f} tok/s, "
          f"final loss {last.get('loss', last['total_loss']):.4f}")
    if args.adaptive and tr.runtime is not None:
        s = tr.runtime.summary()
        print(f"[autotune] measured CCR "
              f"{(s['measured_ccr'] or 0.0):.3f}, interval {s['interval']}, "
              f"{s['replans']} re-plan(s)")
    if resilience is not None:
        rs = resilience.summary()
        print(f"[resilience] {rs['trips']} guard trip(s) "
              f"{rs['trips_by_guard']}, {rs['actions']} recovery action(s) "
              f"{rs['actions_by_rung']}"
              + (f", faults fired {rs['faults']['by_kind']}"
                 if "faults" in rs else ""))
    if args.history_out:
        os.makedirs(os.path.dirname(args.history_out) or ".", exist_ok=True)
        with open(args.history_out, "w") as f:
            json.dump({"config": vars(args), "interval": interval,
                       "history": tr.history}, f, indent=1)
        print(f"[history] {args.history_out}")
    if telemetry is not None:
        if args.adaptive and tr.runtime is not None:
            tr.runtime.finish()     # planned per-bucket spans -> trace
        paths = telemetry.save()
        telemetry.close()
        print(f"[telemetry] {paths['snapshot']}  {paths['prom']}  "
              f"{paths['trace']} (open in Perfetto)")


if __name__ == "__main__":
    main()
