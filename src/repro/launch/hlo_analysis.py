"""HLO artifact analysis: collective-byte accounting + roofline terms.

``cost_analysis()`` gives HLO FLOPs and HBM bytes but NOT collective volume,
so collectives are parsed from the compiled module text: every
all-reduce / all-gather / reduce-scatter / all-to-all / collective-permute
op's result bytes are summed (start/done pairs counted once).

Wire-byte model (ring algorithms): all-reduce moves 2(n-1)/n of its buffer
per device; the others move ~(n-1)/n ~ 1x.  We report raw buffer bytes per
type plus a wire estimate with factor 2 for all-reduce, 1 otherwise.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Iterable

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    # fp8 wire formats (FP8Block / fp8wire compressor)
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1, "f8e4m3fnuz": 1,
    "f8e5m2fnuz": 1,
}

_COLL_RE = re.compile(
    r"\b(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\b"
)
_SHAPE_RE = re.compile(r"(pred|bf16|c64|f8e\d+m\d+\w*|[suf]\d+)\[([\d,]*)\]")


@dataclasses.dataclass
class CollectiveOp:
    kind: str
    result_bytes: int
    line: str


def _result_bytes(lhs: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(lhs):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


_OPCODE_RE = re.compile(r"([a-z][a-z0-9\-]*)\(")


def parse_collectives(hlo_text: str) -> list[CollectiveOp]:
    ops = []
    for line in hlo_text.splitlines():
        s = line.strip()
        if "=" not in s:
            continue
        _, rhs = s.split("=", 1)
        # the opcode is the FIRST identifier followed by '(' on the rhs —
        # matching anywhere would also hit fusions whose *operands* are
        # named after a collective (%all-reduce.11) and inflate the count
        m = _OPCODE_RE.search(rhs)
        if not m:
            continue
        cm = _COLL_RE.fullmatch(m.group(1))
        # '-done' ops re-state the shape; only count the op (or its -start)
        if not cm:
            continue
        kind = cm.group(1)
        # result shape(s) sit between '=' and the opcode
        shape_str = rhs[: m.start()]
        ops.append(CollectiveOp(kind, _result_bytes(shape_str), s[:200]))
    return ops


def collective_bytes_per_worker(hlo_text: str, world: int) -> float:
    """Per-worker *injected* bytes of every collective in the module — the
    number a compressor's static ``CommSchedule.bytes_per_worker`` must
    reproduce (tests/test_hlo_and_specs.py).

    Normalisation per op kind: an all-gather's result buffer is the W-fold
    gathered tensor, of which one worker contributed 1/W; a reduce-scatter's
    result is 1/W of the buffer each worker fed in; all-reduce /
    all-to-all / collective-permute results match the per-worker buffer.
    """
    total = 0.0
    for op in parse_collectives(hlo_text):
        if op.kind == "all-gather":
            total += op.result_bytes / max(world, 1)
        elif op.kind == "reduce-scatter":
            total += op.result_bytes * max(world, 1)
        else:
            total += op.result_bytes
    return total


# ---------------------------------------------------------------------------
# per-link byte accounting (two-level hierarchy, DESIGN.md §17)
# ---------------------------------------------------------------------------

_RG_EXPLICIT_RE = re.compile(
    r"replica_groups=\{(\{[\d,]*\}(?:,\{[\d,]*\})*)\}"
)
_RG_EMPTY_RE = re.compile(r"replica_groups=\{\}")
# iota form: replica_groups=[G,S]<=[d0,d1,...]T(p0,p1,...)
_RG_IOTA_RE = re.compile(
    r"replica_groups=\[(\d+),(\d+)\]<=\[([\d,]+)\](?:T\(([\d,]+)\))?"
)


def parse_replica_groups(line: str) -> list[list[int]] | None:
    """Participant groups of one collective instruction, or ``None`` when
    the line carries no ``replica_groups`` attribute.  ``[]`` means the
    explicit "all devices, one group" form (``replica_groups={}``).

    Handles both the explicit form (``{{0,1},{2,3}}``) and XLA's iota
    form (``[G,S]<=[dims]T(perm)``: reshape ``iota(prod(dims))`` to
    ``dims``, transpose by ``perm``, re-split into G groups of S)."""
    m = _RG_EXPLICIT_RE.search(line)
    if m:
        return [
            [int(x) for x in grp.split(",") if x]
            for grp in m.group(1)[1:-1].split("},{")
        ]
    m = _RG_IOTA_RE.search(line)
    if m:
        import numpy as np

        g, s = int(m.group(1)), int(m.group(2))
        dims = [int(x) for x in m.group(3).split(",")]
        ranks = np.arange(int(np.prod(dims))).reshape(dims)
        if m.group(4):
            perm = [int(x) for x in m.group(4).split(",")]
            ranks = ranks.transpose(perm)
        return ranks.reshape(g, s).tolist()
    if _RG_EMPTY_RE.search(line):
        return []
    return None


def group_link(group: list[int], intra_world: int) -> str:
    """Which link a collective group crosses, for a (pod, intra...) mesh
    laid out row-major with ``intra_world`` devices per pod: a group whose
    members span two pod blocks (``rank // intra_world`` differs) crosses
    the DCN; one confined to a single block stays on the ICI."""
    k = max(int(intra_world), 1)
    pods = {r // k for r in group}
    return "dcn" if len(pods) > 1 else "ici"


def collective_bytes_by_link(
    hlo_text: str, *, intra_world: int, min_bytes: int = 0, world: int = 0
) -> dict[str, float]:
    """Per-worker *injected* collective bytes of a compiled module split
    by link — the number the merged hierarchical
    ``CommSchedule.exposed_bytes_by_link`` must reproduce
    (``benchmarks/hier_check.py``).

    Per-op normalisation matches :func:`collective_bytes_per_worker`
    except each op is normalised by its OWN group size (parsed from
    ``replica_groups``), not a module-wide world: in a hierarchical step
    the intra-pod reduce-scatter runs over ``intra_world`` workers while
    the cross-pod exchange runs over ``n_pods``.  Ops whose normalised
    bytes fall below ``min_bytes`` (scalar loss/grad-norm psums) are
    skipped.  ``world`` disambiguates the "all devices" group forms
    (``replica_groups={}`` or absent)."""
    out = {"ici": 0.0, "dcn": 0.0}
    for line in hlo_text.splitlines():
        s = line.strip()
        if "=" not in s:
            continue
        _, rhs = s.split("=", 1)
        m = _OPCODE_RE.search(rhs)
        if not m:
            continue
        cm = _COLL_RE.fullmatch(m.group(1))
        if not cm:
            continue
        kind = cm.group(1)
        result = _result_bytes(rhs[: m.start()])
        groups = parse_replica_groups(s)
        if groups:
            g = len(groups[0])
            link = group_link(groups[0], intra_world)
        else:
            g = max(int(world), 1)
            link = (
                "dcn" if g > max(int(intra_world), 1) else "ici"
            )
        if kind == "all-gather":
            injected = result / max(g, 1)
        elif kind == "reduce-scatter":
            injected = result * max(g, 1)
        else:
            injected = float(result)
        if injected < min_bytes:
            continue
        out[link] += injected
    return out


_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\([^)]*\)\s*->")
_WHILE_RE = re.compile(
    r"\bwhile\(.*?\),\s*condition=%?([\w\.\-]+),\s*body=%?([\w\.\-]+)"
)
_CALL_RE = re.compile(r"\b(?:call|to_apply|calls)=%?([\w\.\-]+)")
_CONST_INT = re.compile(r"\bconstant\((\d+)\)")


def _split_computations(hlo_text: str) -> tuple[dict[str, list[str]], str | None]:
    """-> ({computation_name: lines}, entry_name)."""
    comps: dict[str, list[str]] = {}
    entry = None
    cur = None
    for line in hlo_text.splitlines():
        m = _COMP_HDR.match(line.strip()) if line and not line.startswith(" ") else None
        if m and line.rstrip().endswith("{"):
            cur = m.group(2)
            comps[cur] = []
            if m.group(1):
                entry = cur
            continue
        if line.startswith("}"):
            cur = None
            continue
        if cur is not None:
            comps[cur].append(line)
    return comps, entry


def _trip_count(cond_lines: list[str]) -> int:
    consts = [int(x) for l in cond_lines for x in _CONST_INT.findall(l)]
    consts = [c for c in consts if 1 < c <= 1_000_000]
    return max(consts) if consts else 1


def computation_multipliers(hlo_text: str) -> dict[str, int]:
    """Execution-count multiplier per computation, following while-loop
    nesting from ENTRY (lax.scan bodies execute trip-count times — XLA's
    cost_analysis ignores this; we don't)."""
    comps, entry = _split_computations(hlo_text)
    if entry is None:
        return {name: 1 for name in comps}
    mult = {name: 0 for name in comps}

    def visit(name: str, m: int, depth=0):
        if name not in comps or depth > 12:
            return
        mult[name] = mult.get(name, 0) + m
        for line in comps[name]:
            w = _WHILE_RE.search(line)
            if w:
                cond, body = w.group(1), w.group(2)
                trips = _trip_count(comps.get(cond, []))
                visit(body, m * trips, depth + 1)
                visit(cond, m * (trips + 1), depth + 1)
                continue
            for callee in _CALL_RE.findall(line):
                if callee in comps and callee != name:
                    visit(callee, m, depth + 1)

    visit(entry, 1)
    return {k: max(v, 0) for k, v in mult.items()}


def _weighted_computations(hlo_text: str, trip_aware: bool):
    """Yield ``(lines, multiplier)`` per computation — the shared scan
    under :func:`collective_summary` and :func:`count_data_movement`
    (multiplier = while-loop trip weighting, 1 for unreferenced)."""
    comps, _ = _split_computations(hlo_text)
    mults = computation_multipliers(hlo_text) if trip_aware else {}
    for name, lines in (comps.items() if comps else [("", hlo_text.splitlines())]):
        m = mults.get(name, 1) if trip_aware else 1
        yield lines, (m if m != 0 else 1)  # 0 = unreferenced (conservative)


def collective_summary(hlo_text: str, trip_aware: bool = True) -> dict:
    by_kind: dict[str, dict] = {}
    total_ops = 0
    buffer_bytes = 0
    wire = 0
    for lines, m in _weighted_computations(hlo_text, trip_aware):
        for op in parse_collectives("\n".join(lines)):
            total_ops += m
            d = by_kind.setdefault(op.kind, {"count": 0, "bytes": 0})
            d["count"] += m
            d["bytes"] += m * op.result_bytes
            buffer_bytes += m * op.result_bytes
            wire += (2 if op.kind == "all-reduce" else 1) * m * op.result_bytes
    return {
        "ops": total_ops,
        "by_kind": by_kind,
        "buffer_bytes": buffer_bytes,
        "wire_bytes_est": wire,
        "trip_aware": trip_aware,
    }


# ---------------------------------------------------------------------------
# overlap interleaving checker (overlap execution engine, DESIGN.md §11)
# ---------------------------------------------------------------------------

_HEAVY_OPS = frozenset(
    {"fusion", "dot", "custom-call", "while", "convolution"}
)
_COLL_KINDS = frozenset(
    {"all-reduce", "all-gather", "reduce-scatter", "all-to-all",
     "collective-permute"}
)


@dataclasses.dataclass(frozen=True)
class InterleaveReport:
    """Where a compiled module schedules its gradient collectives.

    ``num_collectives`` counts bucket-sized collectives (result >=
    ``min_bytes``; scalar loss/metric psums are ignored).  A collective's
    *issue point* is its ``-start`` op where the backend splits start/done
    pairs (TPU async collectives), the op itself otherwise — ``-done`` ops
    are never counted.  ``before_final_grad`` is how many of them the
    schedule places before the final gradient-producing heavy op (the last
    fusion/dot/while that feeds any collective); ``independent`` is how
    many are structurally independent of at least one gradient-producing
    heavy op (neither ancestor nor descendant) — the latency-hiding
    scheduler's licence to overlap them with backward compute.
    """

    num_collectives: int
    num_grad_ops: int
    before_final_grad: int
    independent: int
    first_collective_pos: int
    last_grad_pos: int

    @property
    def interleaved(self) -> bool:
        """At least one collective-start is scheduled before the final
        backward (gradient-producing) fusion."""
        return self.num_collectives > 0 and self.before_final_grad >= 1


_INST_NAME_RE = re.compile(r"^\s*(%?[\w\.\-]+)\s*=")
_OPERAND_RE = re.compile(r"%[\w\.\-]+")


def _entry_instructions(hlo_text: str) -> list[tuple[str, str, int, list[str]]]:
    """-> [(name, opcode, result_bytes, operand_names)] in schedule order
    for the ENTRY computation (post-scheduling HLO text preserves the
    backend's sequential order)."""
    comps, entry = _split_computations(hlo_text)
    lines = comps.get(entry, []) if entry else []
    out = []
    for raw in lines:
        s = raw.strip()
        if "=" not in s:
            continue
        m = _INST_NAME_RE.match(s)
        if not m:
            continue
        name = m.group(1).lstrip("%")
        _, rhs = s.split("=", 1)
        om = _OPCODE_RE.search(rhs)
        if not om:
            continue
        opcode = om.group(1)
        result = _result_bytes(rhs[: om.start()])
        operands = [x.lstrip("%") for x in _OPERAND_RE.findall(rhs)]
        out.append((name, opcode, result, operands))
    return out


def check_interleaving(hlo_text: str, *, min_bytes: int = 1024) -> InterleaveReport:
    """Does the compiled module issue bucket collectives *inside* the
    backward pass?

    The overlap engine's claim is structural: with the gradient-ready hooks
    a bucket's collective depends only on that bucket's gradients, so the
    schedule can (and does) place collective-starts before the final
    gradient-producing fusion instead of serialising the whole exchange
    after the whole backward pass.  This checker proves it on post-
    optimisation HLO: see :class:`InterleaveReport`.  Used as the
    ``benchmarks.run --smoke`` CI gate and by tests/test_overlap.py.
    """
    insts = _entry_instructions(hlo_text)
    index = {name: i for i, (name, _, _, _) in enumerate(insts)}
    n = len(insts)

    ancestors: list[set[int]] = [set() for _ in range(n)]
    for i, (_, _, _, operands) in enumerate(insts):
        for d in operands:
            j = index.get(d)
            if j is not None and j < i:
                ancestors[i].add(j)
                ancestors[i] |= ancestors[j]

    def is_issue_op(opcode: str) -> bool:
        cm = _COLL_RE.fullmatch(opcode)
        return cm is not None and cm.group(1) in _COLL_KINDS

    colls = [
        i for i, (_, op, rb, _) in enumerate(insts)
        if is_issue_op(op) and rb >= min_bytes
    ]
    grad_ops: set[int] = set()
    for c in colls:
        grad_ops |= {j for j in ancestors[c] if insts[j][1] in _HEAVY_OPS}

    last_grad = max(grad_ops) if grad_ops else -1
    before = sum(1 for c in colls if c < last_grad)
    independent = 0
    for c in colls:
        for j in grad_ops:
            if j not in ancestors[c] and c not in ancestors[j]:
                independent += 1
                break
    return InterleaveReport(
        num_collectives=len(colls),
        num_grad_ops=len(grad_ops),
        before_final_grad=before,
        independent=independent,
        first_collective_pos=min(colls) if colls else -1,
        last_grad_pos=last_grad,
    )


# ---------------------------------------------------------------------------
# sharded-sync placement checker (reduce-scatter/all-gather, DESIGN.md §13)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShardedPlacementReport:
    """Where a compiled sharded step schedules its two collective halves.

    The sharded contract is structural: the gradient reduce-scatters must
    be issuable inside the backward pass (like the overlap engine's
    all-reduces — ``rs_before_final_grad`` counts RS starts scheduled
    before the final gradient-producing heavy op), and the deferred param
    all-gathers must sit at the HEAD of the step, before the backward even
    begins (``ag_before_first_rs`` counts AG starts scheduled before the
    first RS start — the forward pass they overlap lies between the two).
    Bucket-sized collectives only (``min_bytes``).
    """

    num_reduce_scatter: int
    num_all_gather: int
    rs_before_final_grad: int
    ag_before_first_rs: int
    first_ag_pos: int
    first_rs_pos: int
    last_grad_pos: int

    @property
    def placed(self) -> bool:
        """RS inside the backward pass AND AG at the step head."""
        return (
            self.num_reduce_scatter > 0
            and self.num_all_gather > 0
            and self.rs_before_final_grad >= 1
            and self.ag_before_first_rs >= 1
        )


def check_sharded_placement(
    hlo_text: str, *, min_bytes: int = 1024, world: int = 1
) -> ShardedPlacementReport:
    """Prove the sharded-sync dataflow on a compiled module: deferred param
    all-gathers at the head (overlapping the forward), gradient
    reduce-scatters issued before the final gradient-producing fusion
    (overlapping the backward).  ``world`` is the mesh size the module was
    compiled for: a reduce-scatter's RESULT is the 1/W shard of its
    bucket, so the bucket-size filter for RS ops is ``min_bytes / world``
    (all-gather results are the full gathered buffer and filter at
    ``min_bytes`` directly).  Used by the ``sharded`` smoke gate
    (``benchmarks/sharded_check.py``) and tests/test_sharded_sync.py."""
    insts = _entry_instructions(hlo_text)
    index = {name: i for i, (name, _, _, _) in enumerate(insts)}
    n = len(insts)
    ancestors: list[set[int]] = [set() for _ in range(n)]
    for i, (_, _, _, operands) in enumerate(insts):
        for d in operands:
            j = index.get(d)
            if j is not None and j < i:
                ancestors[i].add(j)
                ancestors[i] |= ancestors[j]

    def issue_kind(opcode: str) -> str | None:
        cm = _COLL_RE.fullmatch(opcode)
        return cm.group(1) if cm else None

    rs = [
        i for i, (_, op, rb, _) in enumerate(insts)
        if issue_kind(op) == "reduce-scatter"
        and rb >= min_bytes // max(world, 1)
    ]
    ag = [
        i for i, (_, op, rb, _) in enumerate(insts)
        if issue_kind(op) == "all-gather" and rb >= min_bytes
    ]
    grad_ops: set[int] = set()
    for c in rs:
        grad_ops |= {j for j in ancestors[c] if insts[j][1] in _HEAVY_OPS}
    last_grad = max(grad_ops) if grad_ops else -1
    first_rs = min(rs) if rs else n
    return ShardedPlacementReport(
        num_reduce_scatter=len(rs),
        num_all_gather=len(ag),
        rs_before_final_grad=sum(1 for c in rs if c < last_grad),
        ag_before_first_rs=sum(1 for c in ag if c < first_rs),
        first_ag_pos=min(ag) if ag else -1,
        first_rs_pos=first_rs if rs else -1,
        last_grad_pos=last_grad,
    )


# ---------------------------------------------------------------------------
# data-movement (copy-chain) accounting — the zero-copy arena gate (§12)
# ---------------------------------------------------------------------------

# the opcodes a gather/scatter bucket rebuild materialises as: explicit
# copies, per-segment concatenates, and the dynamic-slice /
# dynamic-update-slice chains of flat-vector splits.  Static `slice` ops
# are intentionally NOT counted: an arena bucket view IS a slice, and XLA
# serves it without touching HBM when it feeds a collective directly.
DATA_MOVEMENT_OPS = frozenset(
    {"copy", "concatenate", "dynamic-slice", "dynamic-update-slice"}
)


def count_data_movement(
    hlo_text: str,
    *,
    ops: frozenset[str] | None = None,
    trip_aware: bool = True,
) -> dict:
    """Count data-movement opcodes over every computation of a compiled
    module (fusion bodies included; while-loop bodies weighted by trip
    count like :func:`collective_summary`).

    Returns ``{opcode: count, ..., "total": n}`` — the number the arena
    gate compares between an arena-on and an arena-off build of the same
    step: losing the per-segment concat/split chains must show up as
    strictly fewer of these ops (``benchmarks.arena_check`` /
    ``tests/test_arena.py``).
    """
    ops = DATA_MOVEMENT_OPS if ops is None else ops
    out: dict[str, int] = {k: 0 for k in sorted(ops)}
    total = 0
    for lines, m in _weighted_computations(hlo_text, trip_aware):
        for raw in lines:
            s = raw.strip()
            if "=" not in s:
                continue
            _, rhs = s.split("=", 1)
            om = _OPCODE_RE.search(rhs)
            if om and om.group(1) in ops:
                out[om.group(1)] += m
                total += m
    out["total"] = total
    return out


def data_movement_delta(hlo_off: str, hlo_on: str) -> dict:
    """Arena gate digest: data-movement counts of the legacy (``off``) vs
    arena (``on``) build of one step, plus the delta.  ``delta["total"]``
    must be positive for the arena claim to hold."""
    off = count_data_movement(hlo_off)
    on = count_data_movement(hlo_on)
    return {
        "off": off,
        "on": on,
        "delta": {k: off[k] - on[k] for k in off},
    }


# ---------------------------------------------------------------------------
# roofline
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)


def roofline_terms(
    *,
    flops_per_device: float,
    hbm_bytes_per_device: float,
    wire_bytes_per_device: float,
    peak_flops: float = 197e12,
    hbm_bw: float = 819e9,
    ici_bw: float = 50e9,
) -> RooflineTerms:
    return RooflineTerms(
        compute_s=flops_per_device / peak_flops,
        memory_s=hbm_bytes_per_device / hbm_bw,
        collective_s=wire_bytes_per_device / ici_bw,
    )


def count_hlo_ops(hlo_text: str, names: Iterable[str]) -> dict[str, int]:
    out = {n: 0 for n in names}
    for line in hlo_text.splitlines():
        for n in names:
            if re.search(rf"\b{re.escape(n)}\b", line):
                out[n] += 1
    return out
