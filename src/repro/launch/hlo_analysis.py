"""HLO artifact analysis: collective-byte accounting + roofline terms.

``cost_analysis()`` gives HLO FLOPs and HBM bytes but NOT collective volume,
so collectives are parsed from the compiled module text: every
all-reduce / all-gather / reduce-scatter / all-to-all / collective-permute
op's result bytes are summed (start/done pairs counted once).

Wire-byte model (ring algorithms): all-reduce moves 2(n-1)/n of its buffer
per device; the others move ~(n-1)/n ~ 1x.  We report raw buffer bytes per
type plus a wire estimate with factor 2 for all-reduce, 1 otherwise.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Iterable

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    # fp8 wire formats (FP8Block / fp8wire compressor)
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1, "f8e4m3fnuz": 1,
    "f8e5m2fnuz": 1,
}

_COLL_RE = re.compile(
    r"\b(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\b"
)
_SHAPE_RE = re.compile(r"(pred|bf16|c64|f8e\d+m\d+\w*|[suf]\d+)\[([\d,]*)\]")


@dataclasses.dataclass
class CollectiveOp:
    kind: str
    result_bytes: int
    line: str


def _result_bytes(lhs: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(lhs):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


_OPCODE_RE = re.compile(r"([a-z][a-z0-9\-]*)\(")


def parse_collectives(hlo_text: str) -> list[CollectiveOp]:
    ops = []
    for line in hlo_text.splitlines():
        s = line.strip()
        if "=" not in s:
            continue
        _, rhs = s.split("=", 1)
        # the opcode is the FIRST identifier followed by '(' on the rhs —
        # matching anywhere would also hit fusions whose *operands* are
        # named after a collective (%all-reduce.11) and inflate the count
        m = _OPCODE_RE.search(rhs)
        if not m:
            continue
        cm = _COLL_RE.fullmatch(m.group(1))
        # '-done' ops re-state the shape; only count the op (or its -start)
        if not cm:
            continue
        kind = cm.group(1)
        # result shape(s) sit between '=' and the opcode
        shape_str = rhs[: m.start()]
        ops.append(CollectiveOp(kind, _result_bytes(shape_str), s[:200]))
    return ops


def collective_bytes_per_worker(hlo_text: str, world: int) -> float:
    """Per-worker *injected* bytes of every collective in the module — the
    number a compressor's static ``CommSchedule.bytes_per_worker`` must
    reproduce (tests/test_hlo_and_specs.py).

    Normalisation per op kind: an all-gather's result buffer is the W-fold
    gathered tensor, of which one worker contributed 1/W; a reduce-scatter's
    result is 1/W of the buffer each worker fed in; all-reduce /
    all-to-all / collective-permute results match the per-worker buffer.
    """
    total = 0.0
    for op in parse_collectives(hlo_text):
        if op.kind == "all-gather":
            total += op.result_bytes / max(world, 1)
        elif op.kind == "reduce-scatter":
            total += op.result_bytes * max(world, 1)
        else:
            total += op.result_bytes
    return total


_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\([^)]*\)\s*->")
_WHILE_RE = re.compile(
    r"\bwhile\(.*?\),\s*condition=%?([\w\.\-]+),\s*body=%?([\w\.\-]+)"
)
_CALL_RE = re.compile(r"\b(?:call|to_apply|calls)=%?([\w\.\-]+)")
_CONST_INT = re.compile(r"\bconstant\((\d+)\)")


def _split_computations(hlo_text: str) -> tuple[dict[str, list[str]], str | None]:
    """-> ({computation_name: lines}, entry_name)."""
    comps: dict[str, list[str]] = {}
    entry = None
    cur = None
    for line in hlo_text.splitlines():
        m = _COMP_HDR.match(line.strip()) if line and not line.startswith(" ") else None
        if m and line.rstrip().endswith("{"):
            cur = m.group(2)
            comps[cur] = []
            if m.group(1):
                entry = cur
            continue
        if line.startswith("}"):
            cur = None
            continue
        if cur is not None:
            comps[cur].append(line)
    return comps, entry


def _trip_count(cond_lines: list[str]) -> int:
    consts = [int(x) for l in cond_lines for x in _CONST_INT.findall(l)]
    consts = [c for c in consts if 1 < c <= 1_000_000]
    return max(consts) if consts else 1


def computation_multipliers(hlo_text: str) -> dict[str, int]:
    """Execution-count multiplier per computation, following while-loop
    nesting from ENTRY (lax.scan bodies execute trip-count times — XLA's
    cost_analysis ignores this; we don't)."""
    comps, entry = _split_computations(hlo_text)
    if entry is None:
        return {name: 1 for name in comps}
    mult = {name: 0 for name in comps}

    def visit(name: str, m: int, depth=0):
        if name not in comps or depth > 12:
            return
        mult[name] = mult.get(name, 0) + m
        for line in comps[name]:
            w = _WHILE_RE.search(line)
            if w:
                cond, body = w.group(1), w.group(2)
                trips = _trip_count(comps.get(cond, []))
                visit(body, m * trips, depth + 1)
                visit(cond, m * (trips + 1), depth + 1)
                continue
            for callee in _CALL_RE.findall(line):
                if callee in comps and callee != name:
                    visit(callee, m, depth + 1)

    visit(entry, 1)
    return {k: max(v, 0) for k, v in mult.items()}


def collective_summary(hlo_text: str, trip_aware: bool = True) -> dict:
    comps, entry = _split_computations(hlo_text)
    mults = computation_multipliers(hlo_text) if trip_aware else {}
    by_kind: dict[str, dict] = {}
    total_ops = 0
    buffer_bytes = 0
    wire = 0
    for name, lines in (comps.items() if comps else [("", hlo_text.splitlines())]):
        m = mults.get(name, 1) if trip_aware else 1
        if m == 0:
            m = 1  # unreferenced (conservative)
        for op in parse_collectives("\n".join(lines)):
            total_ops += m
            d = by_kind.setdefault(op.kind, {"count": 0, "bytes": 0})
            d["count"] += m
            d["bytes"] += m * op.result_bytes
            buffer_bytes += m * op.result_bytes
            wire += (2 if op.kind == "all-reduce" else 1) * m * op.result_bytes
    return {
        "ops": total_ops,
        "by_kind": by_kind,
        "buffer_bytes": buffer_bytes,
        "wire_bytes_est": wire,
        "trip_aware": trip_aware,
    }


# ---------------------------------------------------------------------------
# roofline
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)


def roofline_terms(
    *,
    flops_per_device: float,
    hbm_bytes_per_device: float,
    wire_bytes_per_device: float,
    peak_flops: float = 197e12,
    hbm_bw: float = 819e9,
    ici_bw: float = 50e9,
) -> RooflineTerms:
    return RooflineTerms(
        compute_s=flops_per_device / peak_flops,
        memory_s=hbm_bytes_per_device / hbm_bw,
        collective_s=wire_bytes_per_device / ici_bw,
    )


def count_hlo_ops(hlo_text: str, names: Iterable[str]) -> dict[str, int]:
    out = {n: 0 for n in names}
    for line in hlo_text.splitlines():
        for n in names:
            if re.search(rf"\b{re.escape(n)}\b", line):
                out[n] += 1
    return out
