"""Crash-isolated dry-run sweep driver.

XLA CHECK failures (compiler bugs on exotic sharding combos) abort the whole
process, so each (arch, shape, mesh) combo runs in its own subprocess with a
timeout; crashes/timeouts are recorded as JSON failure records instead of
killing the sweep.

  python -m repro.launch.dryrun_sweep --out experiments/dryrun --mesh both
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

from repro.configs import INPUT_SHAPES, list_archs


def run_combo(arch, shape, mesh_tag, compressor, interval, out_dir, timeout):
    tag = f"{arch}__{shape}__{mesh_tag}__{compressor}"
    path = os.path.join(out_dir, tag + ".json")
    cmd = [
        sys.executable, "-m", "repro.launch.dryrun",
        "--arch", arch, "--shape", shape, "--mesh", mesh_tag,
        "--compressor", compressor, "--out", out_dir,
    ]
    if interval is not None:
        cmd += ["--interval", str(interval)]
    t0 = time.perf_counter()
    try:
        proc = subprocess.run(
            cmd, capture_output=True, text=True, timeout=timeout,
            env={**os.environ},
        )
        crashed = proc.returncode != 0 and not os.path.exists(path)
        if crashed:
            rec = {
                "arch": arch, "shape": shape, "mesh": mesh_tag,
                "compressor": compressor, "status": "crash",
                "returncode": proc.returncode,
                "stderr_tail": proc.stderr[-3000:],
                "wall_s": round(time.perf_counter() - t0, 1),
            }
            with open(path, "w") as f:
                json.dump(rec, f, indent=1)
            return "CRASH", tag
    except subprocess.TimeoutExpired:
        rec = {
            "arch": arch, "shape": shape, "mesh": mesh_tag,
            "compressor": compressor, "status": "timeout",
            "timeout_s": timeout,
        }
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
        return "TIMEOUT", tag
    try:
        with open(path) as f:
            rec = json.load(f)
        return ("OK" if rec.get("status") == "ok" else "FAIL"), tag
    except FileNotFoundError:
        return "MISSING", tag


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--mesh", default="both", choices=["pod1", "pod2", "both"])
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--compressor", default="covap")
    ap.add_argument("--interval", type=int, default=None)
    ap.add_argument("--timeout", type=int, default=1800)
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    archs = (
        list_archs(assigned_only=True) if args.arch == "all" else args.arch.split(",")
    )
    shapes = list(INPUT_SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = {"pod1": ["pod1"], "pod2": ["pod2"], "both": ["pod1", "pod2"]}[args.mesh]
    os.makedirs(args.out, exist_ok=True)

    results = []
    for arch in archs:
        for shape in shapes:
            for mesh_tag in meshes:
                tag = f"{arch}__{shape}__{mesh_tag}__{args.compressor}"
                path = os.path.join(args.out, tag + ".json")
                if args.skip_existing and os.path.exists(path):
                    try:
                        with open(path) as f:
                            st = json.load(f).get("status")
                    except Exception:
                        st = None
                    if st == "ok":
                        print(f"skip {tag}", flush=True)
                        continue
                status, tag = run_combo(
                    arch, shape, mesh_tag, args.compressor,
                    args.interval, args.out, args.timeout,
                )
                print(f"{status:8s} {tag}", flush=True)
                results.append((status, tag))
    bad = [t for s, t in results if s not in ("OK",)]
    print(f"\n{len(results)} run, {len(bad)} not-OK")
    for t in bad:
        print("  ", t)


if __name__ == "__main__":
    main()
