"""The overlap-interleaving gate: compile one fused-overlap step and check
its HLO schedule (DESIGN.md §11).

Shared harness for the ``benchmarks.run --smoke`` "overlap" gate and
``tests/test_overlap.py`` — both run it in a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` so the CPU backend
has a real 8-worker mesh to emit collectives on:

    python -m repro.launch.overlap_gate

prints one ``OVERLAP ...`` line and exits non-zero unless the compiled
module schedules at least one bucket collective before the final
gradient-producing fusion.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.hlo_analysis import InterleaveReport, check_interleaving


def compile_and_check(
    trainer=None,
    state=None,
    batch=None,
    *,
    arch: str = "gpt2-paper",
    vocab_size: int = 256,
    seq_len: int = 32,
    global_batch: int = 8,
    interval: int = 4,
    phase: int = 0,
    min_bytes: int = 1024,
) -> InterleaveReport:
    """Compile ``trainer``'s fused phase executable (or build a small
    COVAP trainer on a mesh over all local devices) and run
    :func:`~repro.launch.hlo_analysis.check_interleaving` on the optimized
    HLO."""
    if trainer is None:
        from jax.sharding import Mesh

        from repro.configs import get_reduced
        from repro.data import DataConfig, make_loader
        from repro.models import build_model
        from repro.optim import adamw
        from repro.train.trainer import TrainConfig, Trainer

        mesh = Mesh(np.array(jax.devices()), ("data",))
        cfg = get_reduced(arch).with_(vocab_size=vocab_size)
        model = build_model(cfg)
        tc = TrainConfig(
            compressor="covap", interval=interval, bucket_bytes=1 << 14,
            max_buckets=32, log_every=10 ** 9, overlap="fused",
        )
        trainer = Trainer(model, adamw(1e-3), tc, mesh=mesh,
                          dp_axes=("data",))
        state = trainer.init_state(jax.random.PRNGKey(0))
        dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=seq_len,
                        global_batch=global_batch)
        batch = next(iter(make_loader(dc)))
    fn = trainer._phase_fn(phase)
    hlo = fn.lower(
        state["params"], state["opt"], state["comp"], batch, jnp.int32(0)
    ).compile().as_text()
    return check_interleaving(hlo, min_bytes=min_bytes)


def main() -> None:
    r = compile_and_check()
    print(
        f"OVERLAP num_collectives={r.num_collectives} "
        f"before_final_grad={r.before_final_grad} "
        f"independent={r.independent} interleaved={r.interleaved}"
    )
    if not r.interleaved:
        raise SystemExit(
            "fused step's compiled HLO does not interleave collectives "
            "with the backward pass"
        )


if __name__ == "__main__":
    main()
