"""seamless-m4t-medium [audio] — encoder-decoder, multimodal
[arXiv:2308.11596].

12 encoder + 12 decoder layers, d_model=1024, 16 heads (kv=16, head_dim=64),
d_ff=4096, vocab=256206 (padded to 256256 for sharding).  The mel+conv
speech frontend is the allowed stub: the encoder consumes precomputed frame
embeddings.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-medium",
    family="audio",
    num_layers=12,
    encoder_layers=12,
    is_encdec=True,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=256206,
    mlp_act="gelu",
    modality="audio",
    frontend_tokens=1024,
    tie_embeddings=False,
    source="arXiv:2308.11596",
)

REDUCED = CONFIG.with_(
    num_layers=2,
    encoder_layers=2,
    d_model=128,
    num_heads=4,
    num_kv_heads=4,
    head_dim=32,
    d_ff=256,
    vocab_size=512,
    frontend_tokens=16,
    compute_dtype="float32",
    remat=False,
    attn_chunk=32,
    xent_chunk=32,
)
