"""mistral-large-123b [dense] [hf:mistralai/Mistral-Large-Instruct-2407].

88L, d_model=12288, 96 heads (GQA kv=8, head_dim=128), d_ff=28672,
vocab=32768.  bf16 params/optimizer state (DESIGN SS8 memory note).
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="mistral-large-123b",
    family="dense",
    num_layers=88,
    d_model=12288,
    num_heads=96,
    num_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab_size=32768,
    rope_theta=1_000_000.0,
    tie_embeddings=False,
    param_dtype="bfloat16",
    source="hf:mistralai/Mistral-Large-Instruct-2407",
)

REDUCED = CONFIG.with_(
    num_layers=2,
    d_model=256,
    num_heads=8,
    num_kv_heads=2,
    head_dim=32,
    d_ff=512,
    vocab_size=512,
    param_dtype="float32",
    compute_dtype="float32",
    remat=False,
    attn_chunk=32,
    xent_chunk=32,
)
