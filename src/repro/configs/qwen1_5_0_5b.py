"""qwen1.5-0.5b [dense] — QKV bias [hf:Qwen/Qwen1.5-0.5B].

24L, d_model=1024, 16 heads (kv=16, head_dim=64), d_ff=2816, vocab=151936.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen1.5-0.5b",
    family="dense",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=2816,
    vocab_size=151936,
    qkv_bias=True,
    tie_embeddings=True,
    source="hf:Qwen/Qwen1.5-0.5B",
)

REDUCED = CONFIG.with_(
    num_layers=2,
    d_model=128,
    num_heads=4,
    num_kv_heads=4,
    head_dim=32,
    d_ff=256,
    vocab_size=512,
    compute_dtype="float32",
    remat=False,
    attn_chunk=32,
    xent_chunk=32,
)
