"""gemma2-27b [dense] — alternating local/global attention, logit softcaps
[arXiv:2408.00118].

46L, d_model=4608, 32 heads (GQA kv=16, head_dim=128), d_ff=36864,
vocab=256000.  Superblock = (local window 4096, global) pair -> 23 scanned
superblocks.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="gemma2-27b",
    family="dense",
    num_layers=46,
    d_model=4608,
    num_heads=32,
    num_kv_heads=16,
    head_dim=128,
    d_ff=36864,
    vocab_size=256000,
    mlp_act="geglu",
    local_global=True,
    sliding_window=4096,
    attn_softcap=50.0,
    logit_softcap=30.0,
    tie_embeddings=True,
    source="arXiv:2408.00118",
)

REDUCED = CONFIG.with_(
    num_layers=2,
    d_model=128,
    num_heads=4,
    num_kv_heads=2,
    head_dim=32,
    d_ff=256,
    vocab_size=512,
    sliding_window=16,
    compute_dtype="float32",
    remat=False,
    attn_chunk=32,
    xent_chunk=32,
)
