"""Architecture config schema + input-shape registry.

Every assigned architecture is an ``ArchConfig`` (exact dims cited from its
source paper / model card in the per-arch module) plus a REDUCED variant for
CPU smoke tests (<= 2 superblocks, d_model <= 512, <= 4 experts).
"""
from __future__ import annotations

import dataclasses
from typing import Any


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // num_heads

    # --- MoE ---------------------------------------------------------------
    num_experts: int = 0
    num_shared_experts: int = 0
    experts_per_token: int = 0
    moe_capacity_factor: float = 1.25
    aux_loss_coef: float = 0.01

    # --- attention ----------------------------------------------------------
    qkv_bias: bool = False
    logit_softcap: float = 0.0       # final-logit softcap (gemma2)
    attn_softcap: float = 0.0        # attention-logit softcap (gemma2)
    sliding_window: int = 0          # 0 = full attention
    local_global: bool = False       # gemma2 alternating local/global layers
    rope_theta: float = 10000.0
    tie_embeddings: bool = True

    # --- MLP / norm ----------------------------------------------------------
    mlp_act: str = "swiglu"          # swiglu | geglu | gelu
    norm_eps: float = 1e-6

    # --- SSM / hybrid ---------------------------------------------------------
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    attn_every: int = 0              # zamba2: one shared attn block every N mamba
    slstm_every: int = 0             # xlstm: one sLSTM block every N mLSTM

    # --- encoder-decoder / modality -------------------------------------------
    encoder_layers: int = 0
    is_encdec: bool = False
    modality: str = "text"           # text | vision | audio
    frontend_tokens: int = 0         # patches/frames emitted by the stub frontend

    # --- numerics ---------------------------------------------------------------
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    kv_cache_dtype: str = ""         # "" = compute_dtype; "int8" = quantized
    remat: bool = True
    xent_chunk: int = 512            # sequence chunk for the softmax-xent loss
    attn_chunk: int = 256            # q-chunk for the streaming attention

    # --- provenance ----------------------------------------------------------
    source: str = ""

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    def with_(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)

    # -- parameter counting (for roofline MODEL_FLOPS and CCR) -----------------
    def param_count(self) -> int:
        from repro.models import model as _m  # lazy; avoids cycle at import

        return _m.count_params(self)

    def active_param_count(self) -> int:
        from repro.models import model as _m

        return _m.count_params(self, active_only=True)


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # train | prefill | decode


INPUT_SHAPES: dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}
