"""zamba2-2.7b [hybrid] — Mamba2 backbone + weight-shared attention block
[arXiv:2411.15242].

54 Mamba2 layers, d_model=2560, ssm_state=64; one shared transformer block
(32 heads, kv=32, d_ff=10240) applied every 6 mamba blocks (9 applications,
shared weights).
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-2.7b",
    family="hybrid",
    num_layers=54,
    d_model=2560,
    num_heads=32,
    num_kv_heads=32,
    head_dim=80,
    d_ff=10240,
    vocab_size=32000,
    ssm_state=64,
    ssm_head_dim=64,
    ssm_expand=2,
    attn_every=6,
    tie_embeddings=True,
    source="arXiv:2411.15242",
)

REDUCED = CONFIG.with_(
    num_layers=2,
    d_model=128,
    num_heads=4,
    num_kv_heads=4,
    head_dim=32,
    d_ff=256,
    vocab_size=512,
    ssm_state=16,
    ssm_head_dim=32,
    attn_every=2,
    compute_dtype="float32",
    remat=False,
    attn_chunk=32,
    xent_chunk=32,
)
