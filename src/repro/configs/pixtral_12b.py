"""pixtral-12b [vlm] — Pixtral-ViT frontend (stub) + Mistral-Nemo-style
backbone [hf:mistralai/Pixtral-12B-2409].

40L, d_model=5120, 32 heads (GQA kv=8, head_dim=128), d_ff=14336,
vocab=131072.  The ViT/projector frontend is the allowed stub: the backbone
consumes precomputed patch embeddings (DESIGN.md SS5).
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="pixtral-12b",
    family="vlm",
    num_layers=40,
    d_model=5120,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=131072,
    rope_theta=1_000_000.0,
    tie_embeddings=False,
    modality="vision",
    frontend_tokens=256,
    source="hf:mistralai/Pixtral-12B-2409",
)

REDUCED = CONFIG.with_(
    num_layers=2,
    d_model=256,
    num_heads=4,
    num_kv_heads=2,
    head_dim=64,
    d_ff=512,
    vocab_size=512,
    frontend_tokens=8,
    compute_dtype="float32",
    remat=False,
    attn_chunk=32,
    xent_chunk=32,
)
