"""gemma-2b [dense] — GeGLU MLP, MQA (kv=1), head_dim=256 [arXiv:2403.08295].

18L, d_model=2048, 8 heads, d_ff=16384, vocab=256000.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="gemma-2b",
    family="dense",
    num_layers=18,
    d_model=2048,
    num_heads=8,
    num_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab_size=256000,
    mlp_act="geglu",
    tie_embeddings=True,   # reference ties; we untie for vocab sharding (DESIGN SS8)
    source="arXiv:2403.08295",
)

REDUCED = CONFIG.with_(
    num_layers=2,
    d_model=128,
    num_heads=4,
    num_kv_heads=1,
    head_dim=32,
    d_ff=256,
    vocab_size=512,
    compute_dtype="float32",
    remat=False,
    attn_chunk=32,
    xent_chunk=32,
)
