"""grok-1-314b [moe] — 8 experts, top-2 routing [hf:xai-org/grok-1].

64L, d_model=6144, 48 heads (GQA kv=8, head_dim=128), d_ff=32768,
vocab=131072.  bf16 params/optimizer state (DESIGN SS8 memory note).
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="grok-1-314b",
    family="moe",
    num_layers=64,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=32768,
    vocab_size=131072,
    num_experts=8,
    num_shared_experts=0,
    experts_per_token=2,
    tie_embeddings=False,
    param_dtype="bfloat16",
    source="hf:xai-org/grok-1",
)

REDUCED = CONFIG.with_(
    num_layers=2,
    d_model=128,
    num_heads=4,
    num_kv_heads=2,
    head_dim=32,
    d_ff=256,
    vocab_size=512,
    num_experts=4,
    experts_per_token=2,
    param_dtype="float32",
    compute_dtype="float32",
    remat=False,
    attn_chunk=32,
    xent_chunk=32,
)
