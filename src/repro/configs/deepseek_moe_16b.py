"""deepseek-moe-16b [moe] — fine-grained MoE: 2 shared + 64 routed experts,
top-6 routing [arXiv:2401.06066].

28L, d_model=2048, 16 heads (kv=16), per-expert d_ff=1408, vocab=102400.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-moe-16b",
    family="moe",
    num_layers=28,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=1408,
    vocab_size=102400,
    num_experts=64,
    num_shared_experts=2,
    experts_per_token=6,
    tie_embeddings=False,
    source="arXiv:2401.06066",
)

REDUCED = CONFIG.with_(
    num_layers=2,
    d_model=128,
    num_heads=4,
    num_kv_heads=4,
    head_dim=32,
    d_ff=96,
    vocab_size=512,
    num_experts=4,
    num_shared_experts=1,
    experts_per_token=2,
    compute_dtype="float32",
    remat=False,
    attn_chunk=32,
    xent_chunk=32,
)
