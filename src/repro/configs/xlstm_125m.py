"""xlstm-125m [ssm] — sLSTM + mLSTM blocks [arXiv:2405.04517].

12L, d_model=768, 4 heads, vocab=50304 (GPT-NeoX tokenizer).  d_ff=0: the
xLSTM block carries its own expansion (mLSTM up-projection factor 2).
Block ratio adapted as 3:1 mLSTM:sLSTM (paper's xLSTM[7:1] rounded to the
12-layer budget).
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-125m",
    family="ssm",
    num_layers=12,
    d_model=768,
    num_heads=4,
    num_kv_heads=4,
    head_dim=192,
    d_ff=0,
    vocab_size=50304,
    slstm_every=4,
    tie_embeddings=True,
    source="arXiv:2405.04517",
)

REDUCED = CONFIG.with_(
    num_layers=2,
    d_model=128,
    num_heads=2,
    num_kv_heads=2,
    head_dim=64,
    vocab_size=512,
    slstm_every=2,
    compute_dtype="float32",
    remat=False,
    attn_chunk=32,
    xent_chunk=32,
)
