"""gpt2-paper — the paper's own text-generation workload (Table VI: GPT-2,
81.9M parameters, THUC-News).  Used for the faithfulness experiments
(Table VII row GPT-2, time-to-solution Fig. 6(c)).
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="gpt2-paper",
    family="dense",
    num_layers=12,
    d_model=768,
    num_heads=12,
    num_kv_heads=12,
    head_dim=64,
    d_ff=3072,
    vocab_size=50257,
    mlp_act="gelu",
    tie_embeddings=True,
    source="paper Table VI / radford2019gpt2",
)

REDUCED = CONFIG.with_(
    num_layers=2,
    d_model=128,
    num_heads=4,
    num_kv_heads=4,
    head_dim=32,
    d_ff=256,
    vocab_size=512,
    compute_dtype="float32",
    remat=False,
    attn_chunk=32,
    xent_chunk=32,
)
