"""Config registry: ``get_config(name)`` / ``get_reduced(name)`` /
``list_archs()``.  One module per assigned architecture (+ the paper's own
GPT-2) exporting CONFIG and REDUCED."""
from __future__ import annotations

import importlib

from .base import INPUT_SHAPES, ArchConfig, InputShape

_ARCH_MODULES = {
    "pixtral-12b": "pixtral_12b",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "gemma-2b": "gemma_2b",
    "grok-1-314b": "grok_1_314b",
    "qwen1.5-0.5b": "qwen1_5_0_5b",
    "mistral-large-123b": "mistral_large_123b",
    "xlstm-125m": "xlstm_125m",
    "seamless-m4t-medium": "seamless_m4t_medium",
    "gemma2-27b": "gemma2_27b",
    "zamba2-2.7b": "zamba2_2_7b",
    "gpt2-paper": "gpt2_paper",
}


def list_archs(assigned_only: bool = False) -> list[str]:
    names = list(_ARCH_MODULES)
    if assigned_only:
        names.remove("gpt2-paper")
    return names


def _module(name: str):
    if name not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_ARCH_MODULES)}")
    return importlib.import_module(f"repro.configs.{_ARCH_MODULES[name]}")


def get_config(name: str) -> ArchConfig:
    return _module(name).CONFIG


def get_reduced(name: str) -> ArchConfig:
    return _module(name).REDUCED


__all__ = [
    "ArchConfig",
    "InputShape",
    "INPUT_SHAPES",
    "get_config",
    "get_reduced",
    "list_archs",
]
